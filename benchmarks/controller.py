"""Adaptive hybrid recovery vs the two static protocols (Sec. 9 regimes).

The paper's evaluation concedes a split decision: ABS wins the
high-event-rate regime (epochs amortize what per-event logging pays per
event), LOG.io wins stragglers and failures (non-blocking, operator-local
replay vs global epoch restart), and data parallelization is LOG.io's
scaling lever.  This benchmark runs three synthetic traces that each
reward a different protocol, with three arms per trace:

  * ``logio``  — the paper's per-event pessimistic logging, static.
  * ``abs``    — the aligned-epoch baseline (default epoch size), static.
  * ``hybrid`` — the adaptive stack: governed micro-batching plus the
                 closed-loop RecoveryController switching per-group
                 recovery modes and (on the burst trace) scaling replicas
                 against the latency SLO.

Traces (thread mode, memory store; wall-clock seconds to exactly-once
completion is the metric, reported as events/sec):

  * ``straggler`` — moderate arrivals; one operator's service time
    balloons for a window of events (value-keyed, so replays pay it
    again) and the operator crashes twice inside the window.  ABS pays a
    global restart per crash; LOG.io replays just the victim.
  * ``highrate`` — bursty near-saturation arrivals above the per-event
    path's capacity.  Per-event LOG.io falls behind; ABS and the hybrid
    (which switches hot groups to epoch snapshotting) stay
    arrival-bound.
  * ``burst`` — diurnal arrivals with a mid-trace burst against a slow
    replicated stage.  The static arms keep one replica and eat the
    backlog; the hybrid's controller scales up for the burst and back
    down after it.

Acceptance (printed as verdict lines): the hybrid finishes within 10% of
the better pure protocol on EVERY trace, while each pure protocol loses
at least one trace by more than 10%.

Run:  PYTHONPATH=src:. python benchmarks/controller.py [--quick]
                       [--json BENCH_controller.json]
CSV:  name,us_per_call,derived   (derived = events/sec for *throughput*)
"""
from __future__ import annotations

import argparse
import time
from functools import partial

from repro.core import (ControllerConfig, CountWindowOperator, Engine,
                        GeneratorSource, MapOperator, Pipeline, ReadSource,
                        TerminalSink)
from repro.core.controller import RecoveryController
from repro.core.engine import FailureInjector
from repro.core.logstore import build_store
from repro.core.scaling import Controller, DispatcherOperator, MergerOperator

WINDOW = 4

# straggler trace: service time balloons for events in [LO, HI) — keyed by
# event VALUE so a global (ABS) restart re-pays the stall for every
# replayed event, exactly like a real data-dependent straggler would
STRAGGLE_LO, STRAGGLE_HI, STALL_S = 100, 350, 0.012

#: input-counter positions of the straggling operator's crashes — all
#: inside/after the stall window, so every recovery re-pays stalled work
_CRASHES = (140, 240, 340, 440)


def _double(b):
    return {"v": b["v"] * 2}


def _straggle(b):
    if STRAGGLE_LO <= b["v"] < STRAGGLE_HI:
        time.sleep(STALL_S)
    return {"v": b["v"] * 2}


def _wsum(bs):
    return {"s": sum(b["v"] for b in bs)}


def _linear_build(n, *, fn=_double, rate=0.0, rate_fn=None):
    def build():
        p = Pipeline()
        p.add(partial(GeneratorSource, "src",
                      ReadSource([{"v": i} for i in range(n)]),
                      rate=rate, rate_fn=rate_fn))
        p.add(partial(MapOperator, "map", fn=fn))
        p.add(partial(CountWindowOperator, "win", WINDOW, agg=_wsum))
        p.add(partial(TerminalSink, "sink", target=n // WINDOW))
        p.connect("src", "out", "map", "in")
        p.connect("map", "out", "win", "in")
        p.connect("win", "out", "sink", "in")
        return p
    return build


def _expected_linear(n):
    return [{"s": sum(2 * j for j in range(i * WINDOW, (i + 1) * WINDOW))}
            for i in range(n // WINDOW)]


def _timed(eng, ctl=None, timeout=600.0):
    t0 = time.time()
    eng.start()
    if ctl is not None:
        ctl.start()
    ok = eng.wait(timeout)
    dt = time.time() - t0
    if ctl is not None:
        ctl.stop()
    eng.stop()
    if not ok:
        raise TimeoutError("controller bench cell did not finish")
    return dt


def _check(eng, expected):
    got = [b for b in eng.external.committed()
           if not (isinstance(b, dict) and "inset" in b)]
    assert sorted(map(str, got)) == sorted(map(str, expected)), \
        "bench arm lost exactly-once"


# ---------------------------------------------------------------------------
# trace 1: straggler + crashes (LOG.io's regime)
# ---------------------------------------------------------------------------

def _straggler_build(n):
    # windowless (src -> map -> sink): the exactly-once check is per
    # EVENT, so it cannot be confused by window-boundary differences
    # between a failure-free run and a globally-restarted one
    def build():
        p = Pipeline()
        p.add(partial(GeneratorSource, "src",
                      ReadSource([{"v": i} for i in range(n)]),
                      rate=0.002))
        p.add(partial(MapOperator, "map", fn=_straggle))
        p.add(partial(TerminalSink, "sink", target=n))
        p.connect("src", "out", "map", "in")
        p.connect("map", "out", "sink", "in")
        return p
    return build


def _straggler_arm(arm: str, n: int) -> float:
    build = _straggler_build(n)
    # two crashes of the straggling operator inside the stall window; the
    # injection point differs per protocol (each calls its own hooks) but
    # lands on the same per-input counter
    if arm == "abs":
        inj = FailureInjector([("map", "abs_input", n_) for n_ in _CRASHES])
        eng = Engine(build(), mode="thread", store=build_store("memory"),
                     protocol="abs", injector=inj, restart_delay=0.01)
        dt = _timed(eng)
    else:
        inj = FailureInjector([("map", "post_log", n_) for n_ in _CRASHES])
        kw = dict(mode="thread", store=build_store("memory"), injector=inj,
                  restart_delay=0.01)
        if arm == "hybrid":
            # start the hot group in epoch mode: the controller must
            # notice the straggler and bring it back to per-event logging
            eng = Engine(build(), batching="adaptive",
                         recovery_modes={"map": "epoch"}, epoch_interval=16,
                         **kw)
            ctl = RecoveryController(
                eng, ControllerConfig(sample_interval=0.05,
                                      switch_hysteresis=2,
                                      high_rate_eps=50_000.0),
                mode_groups=("map",))
            dt = _timed(eng, ctl)
        else:
            eng = Engine(build(), **kw)
            dt = _timed(eng)
    _check(eng, [{"v": 2 * i} for i in range(n)])
    return dt


# ---------------------------------------------------------------------------
# trace 2: bursty near-saturation arrivals (ABS's regime)
# ---------------------------------------------------------------------------

#: arrivals land in packs of 192 every 24 ms (~8k ev/s sustained) —
#: above the per-event path's capacity, below the batched/epoch paths'
def _highrate_arrivals(off):
    return 0.024 if off % 192 == 0 else 0.0


def _highrate_arm(arm: str, n: int) -> float:
    build = _linear_build(n, rate_fn=_highrate_arrivals)
    if arm == "abs":
        eng = Engine(build(), mode="thread", store=build_store("memory"),
                     protocol="abs")
        dt = _timed(eng)
    elif arm == "hybrid":
        eng = Engine(build(), mode="thread", store=build_store("memory"),
                     batching="adaptive")
        ctl = RecoveryController(
            eng, ControllerConfig(sample_interval=0.05, switch_hysteresis=2,
                                  high_rate_eps=4000.0, epoch_interval=32),
            mode_groups=("map", "win"))
        dt = _timed(eng, ctl)
    else:
        eng = Engine(build(), mode="thread", store=build_store("memory"))
        dt = _timed(eng)
    _check(eng, _expected_linear(n))
    return dt


# ---------------------------------------------------------------------------
# trace 3: diurnal burst against a slow replicated stage (scaling's regime)
# ---------------------------------------------------------------------------

_BURST_BASE_RATE, _BURST_RATE = 0.04, 0.002
_BURST_LO_FRAC, _BURST_HI_FRAC = 0.3, 0.8


def _mk_burst_rate(n):
    lo, hi = int(n * _BURST_LO_FRAC), int(n * _BURST_HI_FRAC)
    def rate(off):
        return _BURST_RATE if lo <= off < hi else _BURST_BASE_RATE
    return rate


_REPLICA_PT = 0.02


def _replica_fn(b):
    return {"v": b["v"] * 2}


def _burst_build(n, replicas):
    rate = _mk_burst_rate(n)
    def build():
        p = Pipeline()
        p.add(partial(GeneratorSource, "src",
                      ReadSource([{"v": i} for i in range(n)]),
                      rate_fn=rate))
        p.add(partial(DispatcherOperator, "disp", list(replicas)))
        for rid in replicas:
            p.add(partial(MapOperator, rid, fn=_replica_fn,
                          processing_time=_REPLICA_PT))
        p.add(partial(MergerOperator, "mrg", list(replicas)))
        p.add(partial(TerminalSink, "sink", target=n))
        p.connect("src", "out", "disp", "in")
        for rid in replicas:
            p.connect("disp", f"to_{rid}", rid, "in")
            p.connect(rid, "out", "mrg", f"from_{rid}")
        p.connect("mrg", "out", "sink", "in")
        return p
    return build


def _burst_arm(arm: str, n: int) -> float:
    build = _burst_build(n, ["r0"])
    if arm == "abs":
        eng = Engine(build(), mode="thread", store=build_store("memory"),
                     protocol="abs")
        dt = _timed(eng)
    elif arm == "hybrid":
        eng = Engine(build(), mode="thread", store=build_store("memory"),
                     restart_delay=0.01)
        scaler = Controller(
            eng, "disp", "mrg",
            replica_factory=lambda rid: partial(
                MapOperator, rid, fn=_replica_fn,
                processing_time=_REPLICA_PT))
        ctl = RecoveryController(
            eng, ControllerConfig(slo_ms=100.0, sample_interval=0.04,
                                  switch_hysteresis=2, scale_cooldown=0.2,
                                  max_replicas=3),
            mode_groups=(), scaler=scaler, replica_prefix="x",
            initial_replicas=["r0"])
        dt = _timed(eng, ctl)
    else:
        eng = Engine(build(), mode="thread", store=build_store("memory"))
        dt = _timed(eng)
    got = sorted(b["v"] for b in eng.external.committed())
    assert got == sorted(2 * i for i in range(n)), \
        "burst arm lost exactly-once"
    return dt


# ---------------------------------------------------------------------------
# sweep + verdicts
# ---------------------------------------------------------------------------

TRACES = (
    ("straggler", _straggler_arm),
    ("highrate", _highrate_arm),
    ("burst", _burst_arm),
)

ARMS = ("logio", "abs", "hybrid")


def sweep(rows: list, *, straggler_n=500, highrate_n=4000, burst_n=240,
          repeats=1):
    sizes = {"straggler": straggler_n, "highrate": highrate_n,
             "burst": burst_n}
    results = {}
    for trace, arm_fn in TRACES:
        n = sizes[trace]
        for arm in ARMS:
            dt = min(arm_fn(arm, n) for _ in range(repeats))
            results[(trace, arm)] = dt
            row = (f"controller/{trace}/{arm}/throughput", dt * 1e6 / n,
                   round(n / dt, 1))
            rows.append(row)
            print(f"{row[0]},{row[1]:.1f},{row[2]}", flush=True)

    # ---- acceptance verdicts --------------------------------------------
    pure_losses = {"logio": 0, "abs": 0}
    all_within = True
    for trace, _ in TRACES:
        lg, ab = results[(trace, "logio")], results[(trace, "abs")]
        hy = results[(trace, "hybrid")]
        better_pure = min(lg, ab)
        within = hy <= better_pure * 1.10
        all_within &= within
        for pure, dt in (("logio", lg), ("abs", ab)):
            if dt > min(lg, ab, hy) * 1.10:
                pure_losses[pure] += 1
        print(f"# {trace}: logio={lg:.2f}s abs={ab:.2f}s hybrid={hy:.2f}s "
              f"-> hybrid/better_pure={hy / better_pure:.2f} "
              f"{'OK (<=1.10)' if within else 'BELOW TARGET'}", flush=True)
        rows.append((f"controller/{trace}/hybrid_vs_better_pure", 0.0,
                     round(hy / better_pure, 3)))
    both_lose = all(v >= 1 for v in pure_losses.values())
    print(f"# pure-protocol losses: {pure_losses} "
          f"{'OK (each static choice loses a trace)' if both_lose else 'BELOW TARGET'}",
          flush=True)
    print(f"# hybrid within 10% of the better pure protocol on every "
          f"trace: {'YES' if all_within else 'NO'}", flush=True)
    return rows


def run(rows, repeats: int = 1, full: bool = False, quick: bool = False):
    """``benchmarks.run`` section adapter."""
    if quick:
        sweep(rows, straggler_n=300, highrate_n=1500, burst_n=140,
              repeats=1)
    else:
        # min-of-3 per cell: single wall-clock runs are too noisy for the
        # 10%-band verdicts
        sweep(rows, repeats=max(repeats, 3))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--repeats", type=int, default=1)
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--json", default=None,
                    help="write rows as JSON (BENCH_controller.json)")
    args = ap.parse_args()
    rows: list = []
    print("name,us_per_call,derived")
    run(rows, repeats=args.repeats, quick=args.quick)
    if args.json:
        import json
        with open(args.json, "w") as f:
            json.dump([{"name": n, "us_per_call": round(u, 2), "derived": d}
                       for n, u, d in rows], f, indent=2)
        print(f"# wrote {args.json}", flush=True)


if __name__ == "__main__":
    main()
