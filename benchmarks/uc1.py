"""Use Case 1 (Sec. 9.2, Figs. 5-9): linear pipeline with straggler OP3.

OP1 source -> OP2 stateless (fast) -> OP3 stateful (straggler, varying
processing time) -> OP4 stateful writer -> OP5 sink. All time constants are
the paper's divided by TIME_SCALE.
"""
from __future__ import annotations

from benchmarks.common import bench, payload, t
from repro.core import (CountWindowOperator, GeneratorSource, MapOperator,
                        Pipeline, ReadSource, TerminalSink)


def build_uc1(*, n_events: int, rate_s: float, op2_pt: float, op3_pt: float,
              op3_window: int, op4_window: int, kb: float = 10.0):
    events = [payload(kb, i) for i in range(n_events)]
    n3 = n_events // op3_window
    n4 = n3 // op4_window

    def build():
        p = Pipeline()
        p.add(lambda: GeneratorSource("OP1", ReadSource(events),
                                      rate=t(rate_s)))
        p.add(lambda: MapOperator("OP2", fn=lambda b: b,
                                  processing_time=t(op2_pt)))
        p.add(lambda: CountWindowOperator(
            "OP3", op3_window, agg=lambda bs: {"n": len(bs)},
            processing_time=t(op3_pt)))
        p.add(lambda: CountWindowOperator(
            "OP4", op4_window, agg=lambda bs: {"n": len(bs)},
            writes_per_output=1, processing_time=t(op2_pt)))
        p.add(lambda: TerminalSink("OP5", target=max(n4, 1)))
        p.connect("OP1", "out", "OP2", "in")
        p.connect("OP2", "out", "OP3", "in")
        p.connect("OP3", "out", "OP4", "in")
        p.connect("OP4", "out", "OP5", "in")
        return p
    return build


def fig5(rows, repeats):
    """100 events @500ms, OP3 100x straggler (5s), failures in OP4."""
    build = build_uc1(n_events=100, rate_s=0.5, op2_pt=0.05, op3_pt=5.0,
                      op3_window=2, op4_window=10)
    bench("uc1_fig5", build, repeats=repeats, rows=rows,
          plans={"normal": [],
                 "1fail_OP4": [("OP4", "input", 1)],
                 "2fail_OP4": [("OP4", "input", 1), ("OP4", "input", 23)],
                 "3fail_OP4": [("OP4", "input", 1), ("OP4", "input", 23),
                               ("OP4", "input", 45)]},
          abs_epoch=15)


def fig6(rows, repeats):
    """Event-size sensitivity during normal processing (10KB -> 1MB)."""
    for kb in (10, 100, 1024):
        build = build_uc1(n_events=60, rate_s=0.5, op2_pt=0.05, op3_pt=5.0,
                          op3_window=2, op4_window=10, kb=kb)
        bench(f"uc1_fig6_{kb}kb", build, repeats=repeats, rows=rows,
              protocols=("none", "logio", "abs"))


def fig7(rows, repeats):
    """1000 events @100ms, OP3 10x straggler (500ms), failures in OP4."""
    build = build_uc1(n_events=1000, rate_s=0.1, op2_pt=0.05, op3_pt=0.5,
                      op3_window=2, op4_window=100)
    bench("uc1_fig7", build, repeats=repeats, rows=rows,
          plans={"normal": [],
                 "1fail_OP4": [("OP4", "input", 10)],
                 "3fail_OP4": [("OP4", "input", 10), ("OP4", "input", 148),
                               ("OP4", "input", 375)]},
          abs_epoch=150)


def fig8(rows, repeats):
    """Same pipeline, failures in the straggler OP3 itself."""
    build = build_uc1(n_events=1000, rate_s=0.1, op2_pt=0.05, op3_pt=0.5,
                      op3_window=2, op4_window=100)
    bench("uc1_fig8", build, repeats=repeats, rows=rows,
          plans={"normal": [],
                 "1fail_OP3": [("OP3", "input", 10)],
                 "3fail_OP3": [("OP3", "input", 10), ("OP3", "input", 295),
                               ("OP3", "input", 745)]},
          abs_epoch=150)


def fig9(rows, repeats):
    """5000 events @30ms, near-uniform operator times — LOG.io's worst case
    (pessimistic logging cannot hide behind a straggler)."""
    build = build_uc1(n_events=5000, rate_s=0.03, op2_pt=0.05, op3_pt=0.1,
                      op3_window=2, op4_window=250)
    bench("uc1_fig9", build, repeats=repeats, rows=rows,
          plans={"normal": [],
                 "1fail_OP4": [("OP4", "input", 10)],
                 "3fail_OP4": [("OP4", "input", 10), ("OP4", "input", 495),
                               ("OP4", "input", 1750)]},
          abs_epoch=500)


def run(rows, repeats=3, full=False):
    fig5(rows, repeats)
    fig6(rows, repeats if full else 1)
    fig7(rows, repeats)
    fig8(rows, repeats if full else 1)
    fig9(rows, repeats if full else 1)
