"""Perf gate: compare the current BENCH_*.json artifacts against a
baseline run (the most recent ``bench-*`` artifact from main) and emit a
markdown comparison table for the CI job summary.

Non-blocking by design: a >threshold throughput regression prints a
``::warning::`` annotation and flags the row, but the exit code is always
0 — the gate reports the perf trajectory, it does not block merges on a
noisy shared runner.

Metrics compared (higher is better):
  * rows named ``*throughput*`` in the name/us_per_call/derived files
    (BENCH_pipeline.json, BENCH_process.json, BENCH_transport.json,
    BENCH_lineage.json, BENCH_batching.json) — ``derived`` is the
    events/sec (or queries/sec) figure;
  * ``events_per_sec`` per config in BENCH_logstore.json.

Usage:
    python benchmarks/perf_gate.py --baseline DIR [--current DIR]
                                   [--threshold 20]

``--baseline`` may point at a directory tree (the artifact download
action nests artifacts in subdirectories); files are found recursively
by name.
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path
from typing import Dict, Optional

BENCH_FILES = ("BENCH_pipeline.json", "BENCH_process.json",
               "BENCH_transport.json", "BENCH_logstore.json",
               "BENCH_lineage.json", "BENCH_batching.json",
               "BENCH_controller.json")


def _find(root: Path, fname: str) -> Optional[Path]:
    if (root / fname).is_file():
        return root / fname
    hits = list(root.rglob(fname))
    if not hits:
        return None
    # the download action nests artifacts per bench-<sha> directory; if
    # several matched, prefer the newest file, not the first sha in sort
    # order (shas sort randomly)
    return max(hits, key=lambda p: p.stat().st_mtime)


def _throughput_metrics(path: Path) -> Dict[str, float]:
    """{metric name: events/sec} from one BENCH json file."""
    try:
        rows = json.loads(path.read_text())
    except (OSError, ValueError):
        return {}
    out: Dict[str, float] = {}
    for row in rows:
        if not isinstance(row, dict):
            continue
        if "events_per_sec" in row:                 # BENCH_logstore.json
            name = row.get("config", "?")
            try:
                out[f"logstore/{name}"] = float(row["events_per_sec"])
            except (TypeError, ValueError):
                pass
        elif "throughput" in str(row.get("name", "")):
            try:
                out[row["name"]] = float(row["derived"])
            except (TypeError, ValueError):
                pass
    return out


def collect(root: Path) -> Dict[str, float]:
    metrics: Dict[str, float] = {}
    for fname in BENCH_FILES:
        path = _find(root, fname)
        if path is not None:
            metrics.update(_throughput_metrics(path))
    return metrics


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--baseline", required=True,
                    help="directory holding the baseline BENCH_*.json "
                         "(searched recursively)")
    ap.add_argument("--current", default=".",
                    help="directory holding this run's BENCH_*.json")
    ap.add_argument("--threshold", type=float, default=20.0,
                    help="warn when throughput drops by more than this "
                         "percentage (default 20)")
    args = ap.parse_args()

    base = collect(Path(args.baseline))
    cur = collect(Path(args.current))

    print("## Perf gate (throughput vs latest `main` bench artifact)")
    print()
    if not base:
        print("_No baseline metrics found — skipping comparison "
              "(first run on this branch?)._")
        return 0
    if not cur:
        print("_No current metrics found — did the benchmark steps run?_")
        return 0

    print(f"Warn threshold: **-{args.threshold:g}%** (non-blocking).")
    print()
    print("| metric | baseline ev/s | current ev/s | Δ | |")
    print("|---|---:|---:|---:|---|")
    regressions = []
    for name in sorted(set(base) | set(cur)):
        b, c = base.get(name), cur.get(name)
        if b is None or c is None:
            missing = "baseline" if b is None else "current"
            print(f"| `{name}` | {b or '—'} | {c or '—'} | — | "
                  f"_no {missing}_ |")
            continue
        delta = (c - b) / b * 100.0 if b else 0.0
        flag = ""
        if delta < -args.threshold:
            flag = "⚠️ regression"
            regressions.append((name, delta))
        elif delta > args.threshold:
            flag = "🚀"
        print(f"| `{name}` | {b:,.0f} | {c:,.0f} | {delta:+.1f}% | {flag} |")
    print()
    if regressions:
        print(f"**{len(regressions)} metric(s) regressed more than "
              f"{args.threshold:g}%** (non-blocking; shared-runner noise "
              "is common — check the trend across commits).")
        for name, delta in regressions:
            # ::warning:: annotations surface on the workflow run page
            sys.stderr.write(
                f"::warning title=perf regression::{name} dropped "
                f"{-delta:.1f}% vs latest main bench artifact\n")
    else:
        print("No throughput regressions beyond the threshold.")
    return 0


if __name__ == "__main__":
    sys.exit(main())
