"""Use Case 2 (Fig. 11): two parallel paths synchronized at a writer OP4 —
exercises ABS marker alignment; failures in the fast path OP2."""
from __future__ import annotations

from benchmarks.common import bench, payload, t
from repro.core import (GeneratorSource, MapOperator, Pipeline, ReadSource,
                        SyncJoinOperator, TerminalSink)


def build_uc2(*, n_events: int = 1000, rate_s: float = 0.1,
              op2_pt: float = 0.05, op3_pt: float = 0.5,
              n_fast: int = 50, n_slow: int = 100, kb: float = 10.0):
    events = [payload(kb, i) for i in range(n_events)]
    n_out = min(n_events // n_fast, n_events // n_slow)

    def build():
        p = Pipeline()
        p.add(lambda: GeneratorSource("OP1", ReadSource(events),
                                      rate=t(rate_s)))
        p.add(lambda: MapOperator("OP2", fn=lambda b: b,
                                  processing_time=t(op2_pt)))
        p.add(lambda: MapOperator("OP3", fn=lambda b: b,
                                  processing_time=t(op3_pt)))
        p.add(lambda: SyncJoinOperator(
            "OP4", n_fast, n_slow,
            agg=lambda a, b: {"na": len(a), "nb": len(b)},
            writes_per_output=1))
        p.add(lambda: TerminalSink("OP5", target=max(n_out, 1)))
        p.connect("OP1", "out", "OP2", "in")
        p.connect("OP1", "out", "OP3", "in")
        p.connect("OP2", "out", "OP4", "in1")
        p.connect("OP3", "out", "OP4", "in2")
        p.connect("OP4", "out", "OP5", "in")
        return p
    return build


def run(rows, repeats=3, full=False):
    build = build_uc2()
    bench("uc2_fig11", build, repeats=repeats, rows=rows,
          plans={"normal": [],
                 "1fail_OP2": [("OP2", "input", 147)],
                 "3fail_OP2": [("OP2", "input", 147),
                               ("OP2", "input", 457),
                               ("OP2", "input", 825)]},
          abs_epoch=150)
