"""Shared harness for the paper-reproduction benchmarks (Sec. 9).

The paper's pipelines run 5-6 minutes on a GKE cluster; ours run the same
event counts with all time constants divided by TIME_SCALE (default 60) so a
run takes seconds on this container. Overheads are reported RELATIVE (vs the
no-recovery execution baseline), which is scale-invariant to first order.

Protocols: "none" (execution baseline, NullLogStore), "logio",
"logio+lineage", "abs".
"""
from __future__ import annotations

import time
from typing import Callable, Optional, Sequence, Tuple

from repro.core import (Engine, FailureInjector, LineageScope, Pipeline)
from repro.core.logstore import NullLogStore, build_store

TIME_SCALE = 60.0


def t(seconds_in_paper: float) -> float:
    return seconds_in_paper / TIME_SCALE


def payload(kb: float, i: int):
    return {"i": i, "data": bytes(int(kb * 1024))}


def run_pipeline(build: Callable[[], Pipeline], *, protocol: str = "logio",
                 plan: Sequence[Tuple[str, str, int]] = (),
                 lineage: Sequence[LineageScope] = (),
                 abs_epoch: int = 15, timeout: float = 240.0,
                 restart_delay: float = 0.3 / TIME_SCALE * 60,
                 store_spec: str = "memory"):
    """Returns (wall_seconds, engine). ``store_spec`` picks the log backend
    stack (``build_store`` spec, e.g. "memory+sharded+group")."""
    store = NullLogStore() if protocol == "none" else build_store(store_spec)
    kwargs = dict(store=store, injector=FailureInjector(list(plan)),
                  mode="thread", restart_delay=restart_delay)
    if protocol == "abs":
        kwargs["protocol"] = "abs"
        kwargs["abs_options"] = {"epoch_events": abs_epoch}
    if protocol == "logio+lineage":
        kwargs["lineage_scopes"] = list(lineage)
    eng = Engine(build(), **kwargs)
    t0 = time.time()
    eng.start()
    ok = eng.wait(timeout)
    dt = time.time() - t0
    eng.stop()
    if not ok:
        raise TimeoutError(f"pipeline did not finish under {protocol}")
    return dt, eng


def _translate(plan, protocol):
    """Generic failure points -> protocol-specific crash points.
    'input' = after processing the nth input event (the paper's failure
    positions are given in processed-event counts); 'source' likewise."""
    out = []
    for (op, point, nth) in plan:
        if point == "input":
            point = "abs_input" if protocol == "abs" else "pre_state_update"
        elif point == "source":
            point = "abs_source" if protocol == "abs" else "source_pre_log"
        elif protocol == "abs":
            point = "abs_input"     # nearest equivalent
        out.append((op, point, nth))
    return out


def bench(name: str, build, *, protocols=("none", "logio", "abs"),
          plans=None, lineage=(), abs_epoch=15, repeats: int = 3,
          rows: Optional[list] = None, store_spec: str = "memory"):
    """Run (protocol x plan) cells; emit CSV rows
    name,us_per_call,derived where derived = overhead%% vs baseline."""
    plans = plans or {"normal": []}
    base_time = None
    out_rows = rows if rows is not None else []
    for proto in protocols:
        for plan_name, plan in plans.items():
            if proto == "none" and plan:
                continue    # baseline is failure-free by definition
            times = []
            for _ in range(repeats):
                dt, eng = run_pipeline(build, protocol=proto,
                                       plan=_translate(plan, proto),
                                       lineage=lineage, abs_epoch=abs_epoch,
                                       store_spec=store_spec)
                times.append(dt)
            best = min(times)
            if proto == "none":
                base_time = best
            over = (100.0 * (best - base_time) / base_time
                    if base_time else float("nan"))
            row = (f"{name}/{proto}/{plan_name}", best * 1e6, round(over, 1))
            out_rows.append(row)
            print(f"{row[0]},{row[1]:.0f},{row[2]}", flush=True)
    return out_rows
