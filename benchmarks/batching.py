"""High-rate head-to-head: per-event LOG.io vs adaptive micro-batching
vs ABS (Sec. 9's high-throughput regime).

The paper identifies per-event pessimistic logging as LOG.io's overhead
at high rates, where epoch-based ABS amortizes its cost over whole
epochs.  The adaptive micro-batched hot path closes that gap the same
way without giving up per-event recovery: runs of queued events go
through one vectored log transaction, one coalesced ack emission and one
batched dispatch, while the governor degenerates to batch=1 at moderate
rates so latency and straggler behavior are unchanged.

Cells (saturation, rate=0):
  * ``logio-scalar``   — the per-event path (batching off), the baseline
                         the >=3x acceptance target is measured against;
  * ``logio-adaptive`` — the governed batched path;
  * ``abs``            — the ABS protocol at its default epoch size.

Cells (moderate, the paper's 1 event / 100 ms regime, TIME_SCALE'd):
  * per-event vs adaptive wall time — the governor must degenerate to
    scalar behavior, so the two must match within noise.

Run:  PYTHONPATH=src:. python benchmarks/batching.py [--json FILE]
CSV:  name,us_per_call,derived   (derived = events/sec for *throughput*)
"""
from __future__ import annotations

import argparse
import os
import tempfile
import time
from functools import partial

from benchmarks.common import TIME_SCALE
from repro.core import (CountWindowOperator, Engine, GeneratorSource,
                        MapOperator, Pipeline, ReadSource, TerminalSink)
from repro.core.logstore import build_store


def _mk_store(spec: str):
    kw = dict(shards=4, batch_size=32, interval=0.002)
    if spec.startswith(("sqlite", "segment")):
        d = tempfile.mkdtemp(prefix="logio-bench-batching-")
        kw["path"] = os.path.join(d, "log.db")
    return build_store(spec, **kw)

#: the paper's moderate regime: 1 event / 100 ms, divided by TIME_SCALE
MODERATE_RATE = 0.1 / TIME_SCALE

WINDOW = 4


def _double(b):
    return {"v": b["v"] * 2}


def _wsum(bs):
    return {"s": sum(b["v"] for b in bs)}


def _build(n_events: int, rate: float = 0.0):
    def build():
        p = Pipeline()
        p.add(partial(GeneratorSource, "src",
                      ReadSource([{"v": i} for i in range(n_events)]),
                      rate=rate))
        p.add(partial(MapOperator, "map", fn=_double))
        p.add(partial(CountWindowOperator, "win", WINDOW, agg=_wsum))
        p.add(partial(TerminalSink, "sink", target=n_events // WINDOW))
        p.connect("src", "out", "map", "in")
        p.connect("map", "out", "win", "in")
        p.connect("win", "out", "sink", "in")
        return p
    return build


def _run_once(n_events: int, *, batching="off", protocol: str = "logio",
              store_spec: str = "memory", rate: float = 0.0,
              timeout: float = 240.0) -> float:
    build = _build(n_events, rate=rate)
    kwargs = dict(store=_mk_store(store_spec), mode="thread",
                  batching=batching)
    if protocol == "abs":
        kwargs["protocol"] = "abs"
        kwargs["abs_options"] = {"epoch_events": 15}
    eng = Engine(build(), **kwargs)
    t0 = time.time()
    eng.start()
    ok = eng.wait(timeout)
    dt = time.time() - t0
    eng.stop()
    if not ok:
        raise TimeoutError(f"batching bench cell did not finish "
                           f"({protocol}/{batching}/{store_spec})")
    return dt


def _best(repeats: int, fn) -> float:
    return min(fn() for _ in range(repeats))


def sweep(rows: list, n_events: int = 2000, repeats: int = 2,
          moderate_events: int = 200):
    # ---- saturation: events/sec per (protocol x batching) ----------------
    # The >=3x acceptance target is measured on the durable per-event
    # stores (sqlite, segment): there every scalar commit pays an fsync,
    # which is exactly the per-event overhead the paper concedes to ABS.
    # memory and the group-commit stacks already amortize that cost, so
    # their (still real) gains are reported without the target verdict.
    stores = ["memory", "sqlite", "segment", "sqlite+group", "segment+group"]
    target_stores = {"sqlite", "segment"}
    for spec in stores:
        cells = [
            ("logio-scalar", dict(batching="off")),
            ("logio-adaptive", dict(batching="adaptive")),
            ("abs", dict(batching="off", protocol="abs")),
        ]
        eps_by = {}
        for cell, kw in cells:
            dt = _best(repeats,
                       lambda kw=kw: _run_once(n_events, store_spec=spec,
                                               **kw))
            eps = n_events / dt
            eps_by[cell] = eps
            row = (f"batching/{spec}/{cell}/throughput", dt * 1e6 / n_events,
                   round(eps, 1))
            rows.append(row)
            print(f"{row[0]},{row[1]:.1f},{row[2]}", flush=True)
        gain = eps_by["logio-adaptive"] / eps_by["logio-scalar"]
        vs_abs = eps_by["logio-adaptive"] / eps_by["abs"]
        if spec in target_stores:
            verdict = "OK (>=3x)" if gain >= 3.0 else "BELOW TARGET"
        else:
            verdict = "(amortizing store; no 3x target)"
        print(f"# {spec}: adaptive vs per-event {gain:.2f}x -> {verdict}; "
              f"vs abs {vs_abs:.2f}x", flush=True)
        rows.append((f"batching/{spec}/gain_vs_scalar", 0.0, round(gain, 2)))

    # ---- moderate rate: the governor must degenerate to scalar -----------
    for cell, kw in (("moderate-scalar", dict(batching="off")),
                     ("moderate-adaptive", dict(batching="adaptive"))):
        dt = _best(repeats,
                   lambda kw=kw: _run_once(moderate_events, rate=MODERATE_RATE,
                                           **kw))
        lat_us = dt * 1e6 / moderate_events
        row = (f"batching/{cell}", lat_us, round(moderate_events / dt, 1))
        rows.append(row)
        print(f"{row[0]},{row[1]:.1f},{row[2]}", flush=True)
    sc = next(r for r in rows if r[0] == "batching/moderate-scalar")
    ad = next(r for r in rows if r[0] == "batching/moderate-adaptive")
    drift = (ad[1] - sc[1]) / sc[1] * 100.0
    print(f"# moderate-rate latency drift adaptive vs scalar: "
          f"{drift:+.1f}% (target: within noise)", flush=True)
    return rows


def run(rows, repeats: int = 1, full: bool = False, quick: bool = False):
    """``benchmarks.run`` section adapter."""
    n = 5000 if full else (500 if quick else 2000)
    sweep(rows, n_events=n, repeats=max(repeats, 1),
          moderate_events=100 if quick else 200)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--events", type=int, default=2000)
    ap.add_argument("--repeats", type=int, default=2)
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--json", default=None,
                    help="write rows as JSON (BENCH_batching.json)")
    args = ap.parse_args()
    if args.quick:
        args.events, args.repeats = min(args.events, 500), 1
    rows: list = []
    print("name,us_per_call,derived")
    sweep(rows, n_events=args.events, repeats=args.repeats)
    if args.json:
        import json
        with open(args.json, "w") as f:
            json.dump([{"name": n, "us_per_call": round(u, 2), "derived": d}
                       for n, u, d in rows], f, indent=2)
        print(f"# wrote {args.json}", flush=True)


if __name__ == "__main__":
    main()
