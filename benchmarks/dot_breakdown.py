"""Attribute dot FLOPs to model operations via op_name metadata.

    PYTHONPATH=src python -m benchmarks.dot_breakdown dump.hlo [N]
"""
import re
import sys
from collections import defaultdict

from repro.parallel.hlo_analysis import HloModule


def breakdown(path, top=20):
    m = HloModule(open(path).read())
    rows = defaultdict(float)
    for (comp, name), ins in m.instrs.items():
        if ins.opcode != "dot":
            continue
        res = ins.result_dims
        n = 1
        for d in res:
            n *= d
        contract = 1
        cm = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", ins.rhs)
        lhs = m._operand_dims(ins, 0)
        if cm and lhs:
            for ci in cm.group(1).split(","):
                if ci:
                    contract *= lhs[int(ci)]
        fl = 2.0 * n * contract * m.multiplier.get(comp, 1)
        om = re.search(r'op_name="([^"]+)"', ins.rhs)
        label = om.group(1) if om else name
        label = re.sub(r"\[[^\]]*\]", "", label)
        rows[label[:110]] += fl
    out = sorted(rows.items(), key=lambda kv: -kv[1])
    total = sum(rows.values())
    print(f"total dot flops/chip: {total:.3e}")
    for label, fl in out[:top]:
        print(f"{fl:10.2e}  {label}")


if __name__ == "__main__":
    breakdown(sys.argv[1], int(sys.argv[2]) if len(sys.argv) > 2 else 20)
