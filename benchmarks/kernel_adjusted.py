"""Kernel-adjusted roofline terms: what the Pallas kernels change.

The XLA dry-run path cannot express VMEM residency, so the memory term
counts every associative-scan stage / attention-score tensor as HBM traffic.
The Pallas kernels (`selective_scan`, `flash_attention` — validated against
their jnp oracles in interpret mode) keep those intermediates in VMEM; this
tool recomputes the memory term with the kernel's analytic traffic
(inputs + outputs only) substituted for the instructions inside the
innermost loops the kernels replace.

    PYTHONPATH=src python -m benchmarks.kernel_adjusted <cell.json> <dump.hlo> \
        --inner-mult <threshold> --kernel-gb <analytic GB/chip>
"""
import argparse
import json

from repro.parallel.hlo_analysis import (_FUSABLE, _NO_TRAFFIC, _SKIP_OPS,
                                         HloModule)

HBM_BW = 819e9
PEAK = 197e12
ICI = 50e9


def inner_loop_bytes(m: HloModule, mult_threshold: int) -> float:
    """Traffic attributed to computations nested deeper than the layer scan
    (the region a fused kernel replaces)."""
    total = 0.0
    for comp in m.comp_instrs:
        if "fused_computation" in comp:
            continue
        mul = m.multiplier.get(comp, 1)
        if mul < mult_threshold:
            continue
        counts = m._consumer_counts(comp)

        def absorbed(name):
            ins = m.instrs.get((comp, name))
            return (ins is not None and ins.opcode in _FUSABLE
                    and counts[name] == 1)

        def ext(ins, seen):
            b = 0.0
            for opn in ins.operands:
                if opn in seen:
                    continue
                seen.add(opn)
                src = m.instrs.get((comp, opn))
                if src is None:
                    continue
                if absorbed(opn):
                    b += ext(src, seen)
                elif src.opcode not in _NO_TRAFFIC:
                    b += src.result_bytes
            return b

        for n in m.comp_instrs[comp]:
            ins = m.instrs[(comp, n)]
            if ins.opcode in _SKIP_OPS or ins.opcode in _NO_TRAFFIC \
                    or absorbed(n):
                continue
            total += (ins.result_bytes + ext(ins, set())) * mul
    return total


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("cell")
    ap.add_argument("hlo")
    ap.add_argument("--inner-mult", type=int, required=True,
                    help="multiplier threshold identifying the kernel region")
    ap.add_argument("--kernel-gb", type=float, required=True,
                    help="analytic HBM GB/chip of the fused kernel")
    args = ap.parse_args()
    d = json.load(open(args.cell))
    m = HloModule(open(args.hlo).read())
    inner = inner_loop_bytes(m, args.inner_mult)
    base_bytes = d["hlo"]["memory_bytes"]
    adj_bytes = base_bytes - inner + args.kernel_gb * 1e9
    comp = d["roofline"]["compute_s"]
    coll = d["roofline"]["collective_s"]
    mem0 = base_bytes / HBM_BW
    mem1 = adj_bytes / HBM_BW
    step0 = max(comp, mem0, coll)
    step1 = max(comp, mem1, coll)
    mfu = d["model_flops"] / d["n_chips"] / PEAK
    print(f"inner-loop (kernel-replaced) traffic: {inner/1e9:.0f} GB/chip")
    print(f"memory term: {mem0:.2f}s -> {mem1:.2f}s")
    print(f"step lower bound: {step0:.2f}s -> {step1:.2f}s")
    print(f"MFU upper bound: {mfu/step0:.4f} -> {mfu/step1:.4f}")


if __name__ == "__main__":
    main()
