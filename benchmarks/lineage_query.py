"""Lineage query latency sweep: backward/forward/slice vs log size x
backend (memory / sqlite / segment), predicate pushdown on vs off.

The queryable-lineage claim (Sec. 7.3) is that audit queries are a product
feature, not an offline log dump: a filtered backward query must be
answered from indexes (memory secondary maps, SQL WHERE over the lineage
mirror, segment sidecar-summary skipping) rather than a full scan of
EVENT_LINEAGE x EVENT_LOG. This sweep measures both arms of every query —
``pushdown`` (the filtered store ops) and ``scan`` (the legacy full-scan
ops + client-side filtering) — and asserts the no-full-scan property on
the store scan counters:

  * sqlite: rows_scanned for one filtered backward step stays O(result),
    nowhere near the lineage table size;
  * segment: the offline sidecar reader skips sealed segments whose
    summary proves they cannot match.

Run:  PYTHONPATH=src:. python benchmarks/lineage_query.py [--rows N]
CSV:  name,us_per_query,queries_per_sec
"""
from __future__ import annotations

import argparse
import os
import tempfile
import time
from functools import partial

from repro.core import (CountWindowOperator, Engine, GeneratorSource,
                        LineageFilter, LineageQuery, LineageScope,
                        MapOperator, Pipeline, ReadSource, TerminalSink)
from repro.core.logstore import StoreConfig, build_store
from repro.core.metrics import store_metrics_from_backend

WINDOW = 4


def _double(b):
    return {"v": b["v"] * 2}


def _wsum(bs):
    return {"s": sum(b["v"] for b in bs)}


def _build(n_events: int):
    p = Pipeline()
    p.add(partial(GeneratorSource, "src",
                  ReadSource([{"v": i} for i in range(n_events)])))
    p.add(partial(MapOperator, "map", fn=_double))
    p.add(partial(CountWindowOperator, "win", WINDOW, agg=_wsum))
    p.add(partial(TerminalSink, "sink", target=n_events // WINDOW))
    p.connect("src", "out", "map", "in")
    p.connect("map", "out", "win", "in")
    p.connect("win", "out", "sink", "in")
    return p


def populate(store, n_events: int):
    """Run the linear pipeline once with lineage capture on, leaving the
    store holding ~2.25 rows of EVENT_LINEAGE per source event."""
    eng = Engine(_build(n_events), store=store, mode="thread",
                 lineage_scopes=[LineageScope(("src", "out"),
                                              ("win", "out"))])
    eng.start()
    if not eng.wait(300.0):
        raise TimeoutError("lineage population run did not finish")
    eng.stop()
    return eng.store


def _measure(fn, repeats: int) -> float:
    best = None
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        dt = time.perf_counter() - t0
        best = dt if best is None else min(best, dt)
    return best


def sweep(rows_per_backend: int = 2000, queries: int = 50, repeats: int = 2,
          sqlite: bool = True, segment: bool = True):
    n_events = rows_per_backend
    n_wins = n_events // WINDOW
    tmp = tempfile.mkdtemp(prefix="lineage_query_bench_")
    backends = [("memory", lambda: build_store("memory"))]
    if sqlite:
        backends.append(("sqlite", lambda: build_store(
            "sqlite", path=os.path.join(tmp, "log.db"))))
    if segment:
        backends.append(("segment", lambda: build_store(StoreConfig(
            base="segment", path=os.path.join(tmp, "segs"),
            segment_bytes=64 * 1024, checkpoint_interval=0))))

    flt = LineageFilter(ops={"src", "map"})
    results = []
    verdicts = []
    for bname, mk in backends:
        store = populate(mk(), n_events)
        qs = {True: LineageQuery(store, pushdown=True),
              False: LineageQuery(store, pushdown=False)}
        wkeys = [("win", "out", (i * 7919) % n_wins) for i in range(queries)]
        skeys = [("src", "out", (i * 7919) % n_events)
                 for i in range(queries)]
        workloads = [
            ("backward", lambda q: [q.backward(k, where=flt) for k in wkeys]),
            ("forward", lambda q: [q.forward(k, "map") for k in skeys]),
            ("slice", lambda q: [q.slice(k) for k in wkeys]),
        ]
        perf = {}
        for wname, work in workloads:
            for pd in (True, False):
                arm = "pushdown" if pd else "scan"
                dt = _measure(lambda q=qs[pd], w=work: w(q), repeats)
                qps = queries / dt
                perf[(wname, pd)] = qps
                results.append((f"lineage_query/{bname}/{wname}/{arm}"
                                f"/throughput", 1e6 * dt / queries,
                                round(qps, 1)))
                print(f"lineage_query/{bname}/{wname}/{arm},"
                      f"{1e6 * dt / queries:.1f},{qps:.0f}", flush=True)
        ratio = perf[("backward", True)] / perf[("backward", False)]
        verdicts.append((bname, ratio))
        print(f"# {bname}: pushdown vs scan on filtered backward = "
              f"{ratio:.1f}x {'OK (>1x)' if ratio > 1.0 else 'BELOW TARGET'}",
              flush=True)

        # ---- no-full-scan assertions on the scan counters ---------------
        store.reset_query_stats()
        qs[True].backward(("win", "out", n_wins // 2), where=flt)
        pushed = store_metrics_from_backend(store).rows_scanned
        store.reset_query_stats()
        qs[False].backward(("win", "out", n_wins // 2), where=flt)
        scanned = store_metrics_from_backend(store).rows_scanned
        assert pushed < scanned / 10, (
            f"{bname}: filtered backward scanned {pushed} rows with "
            f"pushdown vs {scanned} without — the index is not being used")
        print(f"# {bname}: filtered backward rows_scanned {pushed} "
              f"(pushdown) vs {scanned} (full scan)", flush=True)

        if bname == "segment":
            reader = store.lineage_reader()
            reader.query_lineage(
                LineageFilter(ops={"win"}, ssn_min=0, ssn_max=0))
            st = reader.query_stats()
            assert st["segments_skipped"] >= 1, (
                f"sidecar summaries skipped nothing: {st}")
            print(f"# segment sidecar reader: {st['segments_skipped']} "
                  f"segments skipped, {st['segments_scanned']} scanned, "
                  f"{st['rows_scanned']} rows", flush=True)
        store.close()
    return results, verdicts


def run(rows, repeats: int = 1, full: bool = False, quick: bool = False):
    """``benchmarks.run`` section adapter (perf-gate throughput rows)."""
    n = 5000 if full else (400 if quick else 2000)
    results, _ = sweep(rows_per_backend=n, queries=20 if quick else 50,
                       repeats=max(repeats, 1))
    rows.extend(results)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rows", type=int, default=2000,
                    help="source events per backend (lineage rows ~2.25x)")
    ap.add_argument("--queries", type=int, default=50)
    ap.add_argument("--repeats", type=int, default=2)
    ap.add_argument("--no-sqlite", action="store_true")
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke scale: small log, few queries")
    ap.add_argument("--json", default=None,
                    help="also write results as JSON (perf-trajectory "
                         "artifact)")
    args = ap.parse_args()
    if args.quick:
        args.rows, args.queries, args.repeats = \
            min(args.rows, 400), min(args.queries, 20), 1
    print("name,us_per_query,queries_per_sec", flush=True)
    results, verdicts = sweep(rows_per_backend=args.rows,
                              queries=args.queries, repeats=args.repeats,
                              sqlite=not args.no_sqlite)
    if args.json:
        import json
        with open(args.json, "w") as f:
            json.dump([{"name": n, "us_per_call": u, "derived": d}
                       for n, u, d in results], f, indent=2)
        print(f"# wrote {args.json}", flush=True)


if __name__ == "__main__":
    main()
