"""Log-store throughput sweep: {memory, sqlite, segment} x {plain, sharded,
group-commit, sharded+group} x batch sizes on the UC1 pipeline workload.

The paper's own evaluation (Sec. 9) identifies per-event pessimistic logging
as LOG.io's overhead at high throughput, recovered via parallelization. This
benchmark demonstrates the same claim at the storage layer: the UC1 pipeline
is run once to capture the exact per-operator transaction trace (the five
ops' State-Update + Output-Set transactions), then the trace is replayed
full-speed by one thread per operator against each backend stack —
isolating events/sec of the log path from engine scheduling and sleeps.
Each config replays a second, micro-batched trace (``@batched`` rows:
vectored ``log_events``/``set_status_many`` ops captured with
``Engine(batching="adaptive")``) — the workload the sharded backend's
one-lock-per-run routing is built for.

Run:  PYTHONPATH=src:. python benchmarks/logstore_throughput.py
CSV:  config,events_per_sec,txns,speedup_vs_memory_plain
"""
from __future__ import annotations

import argparse
import os
import tempfile
import threading
import time
from collections import defaultdict
from typing import Dict, List, Tuple

from benchmarks.uc1 import build_uc1
from repro.core import Engine
from repro.core.logstore import (MemoryLogStore, StoreConfig, TxnAborted,
                                 build_store)


class TraceStore(MemoryLogStore):
    """Memory store that records every committed transaction's op list,
    keyed by the committing group thread (== operator id in UC1)."""

    def __init__(self):
        super().__init__()
        self.trace: Dict[str, List[List[Tuple]]] = defaultdict(list)

    def _commit(self, ops):
        name = threading.current_thread().name
        owner = name[4:] if name.startswith("grp-") else name
        token = super()._commit(ops)
        self.trace[owner].append(ops)
        return token


def capture_trace(n_events: int, kb: float, batching="off"):
    """Committed-txn trace of one UC1 run.  ``batching="adaptive"`` captures
    the micro-batched hot path instead: vectored ``log_events`` /
    ``set_status_many`` ops in far fewer transactions, which is what
    exercises the sharded backend's one-lock-per-run routing."""
    build = build_uc1(n_events=n_events, rate_s=0.0, op2_pt=0.0, op3_pt=0.0,
                      op3_window=2, op4_window=10, kb=kb)
    store = TraceStore()
    eng = Engine(build(), store=store, mode="thread", batching=batching)
    eng.start()
    ok = eng.wait(timeout=120.0)
    eng.stop()
    if not ok:
        raise TimeoutError("UC1 trace capture did not finish")
    return {k: v for k, v in store.trace.items()}


def replay(trace: Dict[str, List[List[Tuple]]], store) -> float:
    """One thread per operator, full speed. Transactions that abort because
    a cross-operator dependency has not landed yet are retried (the engine
    orders them naturally; the replay only preserves per-operator order)."""
    def worker(txns):
        for ops in txns:
            while True:
                try:
                    store._commit(list(ops))
                    break
                except TxnAborted:
                    # dependency from another operator's stream not yet
                    # landed: yield instead of GIL-thrashing
                    time.sleep(0.0002)
    threads = [threading.Thread(target=worker, args=(txns,), daemon=True)
               for txns in trace.values()]
    t0 = time.time()
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    store.flush()
    return time.time() - t0


def sweep(n_events: int = 1000, kb: float = 64.0, shards: int = 4,
          batch_sizes=(32,), sqlite: bool = True, segment: bool = True,
          repeats: int = 3):
    print(f"# UC1 trace: {n_events} events, {kb:.0f}KB payloads", flush=True)
    trace = capture_trace(n_events, kb)
    n_txns = sum(len(v) for v in trace.values())
    print(f"# captured {n_txns} txns from {len(trace)} operators", flush=True)
    btrace = capture_trace(n_events, kb, batching="adaptive")
    n_btxns = sum(len(v) for v in btrace.values())
    print(f"# captured {n_btxns} batched txns from {len(btrace)} operators",
          flush=True)

    tmp = tempfile.mkdtemp(prefix="logstore_bench_")
    configs = [("memory/plain", lambda: build_store("memory"))]
    configs.append(("memory/sharded",
                    lambda: build_store("memory+sharded", shards=shards)))
    for bs in batch_sizes:
        configs.append((f"memory/group(b={bs})",
                        lambda bs=bs: build_store("memory+group",
                                                  batch_size=bs)))
        configs.append((f"memory/sharded+group(b={bs})",
                        lambda bs=bs: build_store("memory+sharded+group",
                                                  shards=shards,
                                                  batch_size=bs)))
    if sqlite:
        def sq(spec, bs=32):
            i = len(os.listdir(tmp))
            return build_store(spec, path=os.path.join(tmp, f"s{i}.db"),
                               shards=shards, batch_size=bs)
        configs += [
            ("sqlite/plain", lambda: sq("sqlite")),
            ("sqlite/sharded", lambda: sq("sqlite+sharded")),
            ("sqlite/group(b=32)", lambda: sq("sqlite+group")),
            ("sqlite/sharded+group(b=32)", lambda: sq("sqlite+sharded+group")),
        ]
    if segment:
        def sg(spec, bs=32, compress=False):
            # compress=False for the like-for-like cells: sqlite does not
            # compress its WAL either; the (z) cell shows the sealing cost
            i = len(os.listdir(tmp))
            cfg = StoreConfig.parse(spec, path=os.path.join(tmp, f"s{i}"),
                                    shards=shards, batch_size=bs,
                                    compress=compress)
            return build_store(cfg)
        configs += [
            ("segment/plain", lambda: sg("segment")),
            ("segment/group(b=32)", lambda: sg("segment+group")),
            ("segment/group(z,b=32)",
             lambda: sg("segment+group", compress=True)),
            ("segment/sharded+group(b=32)",
             lambda: sg("segment+sharded+group")),
        ]

    base_eps = {"": None, "@batched": None}
    results = []
    for name, mk in configs:
        # each config replays the per-event trace AND the micro-batched one
        # (vectored log_events/set_status_many in far fewer txns); speedups
        # are within-trace, vs the matching memory/plain baseline
        for suffix, tr, nt in (("", trace, n_txns),
                               ("@batched", btrace, n_btxns)):
            best = None
            for _ in range(repeats):
                store = mk()
                dt = replay(tr, store)
                store.close()
                best = dt if best is None else min(best, dt)
            eps = n_events / best
            if name == "memory/plain":
                base_eps[suffix] = eps
            base = base_eps[suffix]
            speedup = eps / base if base else float("nan")
            results.append((name + suffix, eps, speedup))
            print(f"{name}{suffix},{eps:.0f},{nt},{speedup:.2f}x", flush=True)

    by_name = {r[0]: r for r in results}
    tgt = [r for r in results
           if r[0].startswith("memory/sharded+group") and "@" not in r[0]]
    if tgt and base_eps[""]:
        best = max(r[2] for r in tgt)
        verdict = "OK (>=2x)" if best >= 2.0 else "BELOW TARGET"
        print(f"# sharded+group vs memory/plain: {best:.2f}x -> {verdict}",
              flush=True)
    sh = by_name.get("memory/sharded")
    shb = by_name.get("memory/sharded@batched")
    if sh is not None and shb is not None:
        # the sharded regression fix: pre-fix, per-op routing + the
        # all-shard commit barrier held memory/sharded at 0.45x of
        # plain on this trace.  Single-shard txns now take exactly one
        # shard lock (vectored runs: one lock per shard per run), which
        # must put sharded within routing overhead of plain — the
        # remaining gap is the per-txn home-shard dispatch, which an
        # uncontended single-process replay cannot win back.
        worst = min(sh[2], shb[2])
        verdict = "OK (>=0.75x, was 0.45x)" if worst >= 0.75 \
            else "BELOW TARGET"
        print(f"# memory/sharded vs plain: {sh[2]:.2f}x scalar, "
              f"{shb[2]:.2f}x batched -> {verdict}", flush=True)
    by_name = {r[0]: r[1] for r in results}
    sq_g, sg_g = by_name.get("sqlite/group(b=32)"), \
        by_name.get("segment/group(b=32)")
    if sq_g and sg_g:
        # the segment backend's raison d'etre: sequential appends + one
        # fsync per batch must out-run SQLite page management
        ratio = sg_g / sq_g
        verdict = "OK (>1x)" if ratio > 1.0 else "BELOW TARGET"
        print(f"# segment+group vs sqlite+group: {ratio:.2f}x -> {verdict}",
              flush=True)
    return results


def e2e_sweep(n_events: int = 1000, kb: float = 8.0):
    """Full UC1 runs through the engine (scheduling included) per config."""
    from benchmarks.common import run_pipeline
    build = build_uc1(n_events=n_events, rate_s=0.0, op2_pt=0.0, op3_pt=0.0,
                      op3_window=2, op4_window=10, kb=kb)
    for spec in ("memory", "memory+sharded", "memory+group",
                 "memory+sharded+group"):
        dt, eng = run_pipeline(build, protocol="logio", store_spec=spec)
        print(f"e2e/{spec},{n_events / dt:.0f},events_per_sec", flush=True)


def run(rows, repeats: int = 1, full: bool = False, quick: bool = False):
    """``benchmarks.run`` section adapter: the storage-layer throughput
    sweep as name/us_per_call/derived rows (derived = events/sec, which
    the perf gate compares across commits)."""
    n = 2000 if full else (300 if quick else 1000)
    results = sweep(n_events=n, kb=8.0, repeats=repeats)
    for name, eps, speedup in results:
        rows.append((f"logstore/{name}/throughput", 1e6 / eps if eps else 0.0,
                     round(eps, 1)))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--events", type=int, default=1000)
    ap.add_argument("--kb", type=float, default=64.0,
                    help="payload KB (UC1 fig. 6 sweeps 10KB-1MB)")
    ap.add_argument("--shards", type=int, default=4)
    ap.add_argument("--no-sqlite", action="store_true")
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke scale: fewer events, small payloads, "
                         "single repeat")
    ap.add_argument("--json", default=None,
                    help="also write results as JSON (perf-trajectory "
                         "artifact)")
    ap.add_argument("--e2e", action="store_true",
                    help="also run full UC1 engine sweeps per store config")
    args = ap.parse_args()
    if args.quick:
        args.events, args.kb = min(args.events, 300), min(args.kb, 8.0)
    # best-of-3 even at quick scale: replays cost ~0.1s each, and a single
    # shot on a noisy shared runner is meaningless for the verdict lines
    results = sweep(n_events=args.events, kb=args.kb, shards=args.shards,
                    sqlite=not args.no_sqlite, repeats=3)
    if args.json:
        import json
        with open(args.json, "w") as f:
            json.dump([{"config": name, "events_per_sec": round(eps, 1),
                        "speedup_vs_memory_plain": round(speedup, 3)}
                       for name, eps, speedup in results], f, indent=2)
        print(f"# wrote {args.json}", flush=True)
    if args.e2e:
        e2e_sweep(n_events=args.events, kb=args.kb)


if __name__ == "__main__":
    main()
