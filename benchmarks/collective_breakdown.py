"""Attribute collective traffic to model operations via HLO op_name metadata.

    PYTHONPATH=src python -m benchmarks.collective_breakdown dump.hlo [N]
"""
import re
import sys
from collections import defaultdict

from repro.parallel.hlo_analysis import COLLECTIVES, _RING, HloModule


def breakdown(path, top=25):
    m = HloModule(open(path).read())
    rows = defaultdict(float)
    for (comp, name), ins in m.instrs.items():
        op = ins.opcode[:-6] if ins.opcode.endswith("-start") else ins.opcode
        if op not in COLLECTIVES or ins.opcode.endswith("-done"):
            continue
        gm = re.search(r"replica_groups=\[(\d+),(\d+)\]", ins.rhs)
        n = int(gm.group(2)) if gm else 1
        base = ins.result_bytes if op in ("all-gather", "all-to-all") \
            else max(ins.result_bytes, m._operand_bytes(ins))
        byt = _RING[op](n) * base * m.multiplier.get(comp, 1)
        om = re.search(r'op_name="([^"]+)"', ins.rhs)
        label = om.group(1) if om else name
        # strip jit prefixes/indices for grouping
        label = re.sub(r"\[[^\]]*\]", "", label)
        rows[(op, label[:110])] += byt
    out = sorted(rows.items(), key=lambda kv: -kv[1])
    total = sum(rows.values())
    print(f"total collective bytes/chip: {total/1e9:.1f} GB")
    for (op, label), byt in out[:top]:
        print(f"{byt/1e9:9.1f} GB  {op:18s} {label}")


if __name__ == "__main__":
    breakdown(sys.argv[1], int(sys.argv[2]) if len(sys.argv) > 2 else 25)
