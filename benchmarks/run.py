"""Benchmark driver — one section per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows:
  * uc1_fig5..9, uc2_fig11, uc3_fig12/13 — total pipeline wall time per
    (protocol x failure plan); derived = overhead %% vs the no-recovery
    execution baseline (the paper's Figures 5-9/11-13).
  * lineage_fig10 — lineage-capture overhead vs plain LOG.io (<1.5% claim).
  * process — thread vs process execution mode + recovery latency
    (``benchmarks/process_mode.py``).
  * roofline/* — per (arch x shape) dry-run step-time lower bound (us) and
    dominant roofline term (EXPERIMENTS.md §Roofline reads the same data).

Usage: PYTHONPATH=src python -m benchmarks.run [--full] [--repeats N]
                                  [--only uc1,lineage] [--json FILE]
"""
import argparse
import json


def main():
    import inspect

    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale repeats + the largest configurations")
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke scale for the sections that support it "
                         "(process/transport)")
    ap.add_argument("--repeats", type=int, default=None)
    ap.add_argument("--only", default=None,
                    help="comma list: uc1,uc2,uc3,lineage,lineage_query,"
                         "logstore,batching,controller,process,roofline")
    ap.add_argument("--json", default=None,
                    help="also write the collected rows as JSON "
                         "(per-commit perf-trajectory artifact)")
    args = ap.parse_args()
    repeats = args.repeats or (3 if args.full else (1 if args.quick else 2))
    only = set(args.only.split(",")) if args.only else None

    from benchmarks import (batching, controller, lineage_overhead,
                            lineage_query, logstore_throughput, process_mode,
                            roofline, uc1, uc2, uc3)
    rows = []
    print("name,us_per_call,derived")
    for name, mod in (("uc1", uc1), ("uc2", uc2), ("uc3", uc3),
                      ("lineage", lineage_overhead),
                      ("lineage_query", lineage_query),
                      ("logstore", logstore_throughput),
                      ("batching", batching),
                      ("controller", controller),
                      ("process", process_mode), ("roofline", roofline)):
        if only and name not in only:
            continue
        kwargs = {"repeats": repeats, "full": args.full}
        if "quick" in inspect.signature(mod.run).parameters:
            kwargs["quick"] = args.quick
        try:
            mod.run(rows, **kwargs)
        except Exception as e:   # keep the suite going; record the failure
            print(f"{name}/ERROR,0,{type(e).__name__}:{e}", flush=True)
            rows.append((f"{name}/ERROR", 0.0, f"{type(e).__name__}"))
    if args.json:
        with open(args.json, "w") as f:
            json.dump([{"name": n, "us_per_call": u, "derived": d}
                       for n, u, d in rows], f, indent=2)
        print(f"# wrote {len(rows)} rows to {args.json}", flush=True)
    return rows


if __name__ == '__main__':
    main()
