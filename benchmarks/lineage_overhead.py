"""Fig. 10: data lineage capture overhead — the paper's headline <1.5%.

Same UC1 pipelines run with LOG.io vs LOG.io+lineage (scope covering every
operator); derived column = overhead of lineage relative to plain LOG.io."""
from __future__ import annotations


from benchmarks.common import run_pipeline
from benchmarks.uc1 import build_uc1
from repro.core import LineageScope

SCOPES = [LineageScope(("OP1", "out"), ("OP4", "out"))]


def run(rows, repeats=3, full=False):
    cases = {
        "1000ev": dict(n_events=1000, rate_s=0.1, op2_pt=0.05, op3_pt=0.5,
                       op3_window=2, op4_window=100),
        "5000ev": dict(n_events=5000, rate_s=0.03, op2_pt=0.05, op3_pt=0.1,
                       op3_window=2, op4_window=250),
    }
    for name, kw in cases.items():
        build = build_uc1(**kw)
        base = min(run_pipeline(build, protocol="logio")[0]
                   for _ in range(repeats))
        lin = min(run_pipeline(build, protocol="logio+lineage",
                               lineage=SCOPES)[0] for _ in range(repeats))
        over = 100.0 * (lin - base) / base
        row = (f"lineage_fig10_{name}", lin * 1e6, round(over, 2))
        rows.append(row)
        print(f"{row[0]},{row[1]:.0f},{row[2]}", flush=True)
