"""Roofline table from the dry-run artifacts (EXPERIMENTS.md §Roofline).

Reads results/dryrun/*.json (produced by repro.launch.dryrun) and emits the
three-term analysis per (arch x shape x mesh): compute/memory/collective
seconds, dominant term, 6ND/HLO useful-flops ratio, MFU upper bound.
"""
from __future__ import annotations

import glob
import json
import os


def load(outdir="results/dryrun"):
    cells = []
    for f in sorted(glob.glob(os.path.join(outdir, "*.json"))):
        d = json.load(open(f))
        if d.get("status") == "ok":
            cells.append(d)
    return cells


def table(cells, mesh="16x16"):
    rows = []
    for d in cells:
        if d["mesh"] != mesh:
            continue
        r = d["roofline"]
        m = d["memory_analysis"]
        rows.append({
            "arch": d["arch"], "shape": d["shape"],
            "hbm_gb": m["peak_hbm_bytes"] / 1e9,
            "fits": m["fits_16GB"],
            "compute_s": r["compute_s"], "memory_s": r["memory_s"],
            "collective_s": r["collective_s"], "dominant": r["dominant"],
            "useful": d.get("useful_flops_ratio"),
            "mfu_ub": r["mfu_upper_bound"],
        })
    return rows


def run(rows_out, repeats=None, full=False, outdir="results/dryrun"):
    cells = load(outdir)
    for mesh in ("16x16", "2x16x16"):
        n_ok = sum(1 for c in cells if c["mesh"] == mesh)
        rows_out.append((f"dryrun_cells_ok_{mesh}", n_ok, ""))
        print(f"dryrun_cells_ok_{mesh},{n_ok},", flush=True)
    for r in table(cells):
        name = f"roofline/{r['arch']}/{r['shape']}"
        step_ms = max(r["compute_s"], r["memory_s"], r["collective_s"]) * 1e6
        rows_out.append((name, round(step_ms), f"dom={r['dominant']};"
                         f"mfu_ub={r['mfu_ub'] and round(r['mfu_ub'], 3)};"
                         f"fits={r['fits']}"))
        print(f"{name},{round(step_ms)},{rows_out[-1][2]}", flush=True)


def print_markdown(outdir="results/dryrun", mesh="16x16"):
    cells = load(outdir)
    rows = table(cells, mesh)
    hdr = ("| arch | shape | HBM GB | fits | compute ms | memory ms | "
           "collective ms | dominant | 6ND/HLO | MFU_ub |")
    print(hdr)
    print("|" + "---|" * 10)
    for r in rows:
        print(f"| {r['arch']} | {r['shape']} | {r['hbm_gb']:.2f} | "
              f"{'Y' if r['fits'] else 'N'} | {r['compute_s']*1e3:.1f} | "
              f"{r['memory_s']*1e3:.1f} | {r['collective_s']*1e3:.1f} | "
              f"{r['dominant']} | "
              f"{r['useful'] and round(r['useful'], 3)} | "
              f"{r['mfu_ub'] and round(r['mfu_ub'], 3)} |")


if __name__ == "__main__":
    import sys
    print_markdown(mesh=sys.argv[1] if len(sys.argv) > 1 else "16x16")
