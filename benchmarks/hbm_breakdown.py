"""Attribute HBM traffic to model operations via HLO op_name metadata.

    PYTHONPATH=src python -m benchmarks.hbm_breakdown dump.hlo [N]
"""
import re
import sys
from collections import defaultdict

from repro.parallel.hlo_analysis import (_FUSABLE, _NO_TRAFFIC, _SKIP_OPS,
                                         HloModule)


def breakdown(path, top=30):
    m = HloModule(open(path).read())
    rows = defaultdict(float)
    for comp in m.comp_instrs:
        if "fused_computation" in comp:
            continue
        counts = m._consumer_counts(comp)
        mul = m.multiplier.get(comp, 1)

        def absorbed(name):
            ins = m.instrs.get((comp, name))
            return (ins is not None and ins.opcode in _FUSABLE
                    and counts[name] == 1)

        def external_inputs(ins, seen):
            b = 0.0
            for opn in ins.operands:
                if opn in seen:
                    continue
                seen.add(opn)
                src = m.instrs.get((comp, opn))
                if src is None:
                    continue
                if absorbed(opn):
                    b += external_inputs(src, seen)
                elif src.opcode not in _NO_TRAFFIC:
                    b += src.result_bytes
            return b

        for n in m.comp_instrs[comp]:
            ins = m.instrs[(comp, n)]
            if ins.opcode in _SKIP_OPS or ins.opcode in _NO_TRAFFIC \
                    or absorbed(n):
                continue
            byt = (ins.result_bytes + external_inputs(ins, set())) * mul
            om = re.search(r'op_name="([^"]+)"', ins.rhs)
            label = om.group(1) if om else f"{ins.opcode}:{n}"
            label = re.sub(r"\[[^\]]*\]", "", label)
            rows[(ins.opcode, label[:100])] += byt
    out = sorted(rows.items(), key=lambda kv: -kv[1])
    total = sum(rows.values())
    print(f"total hbm bytes/chip: {total/1e9:.1f} GB")
    for (op, label), byt in out[:top]:
        print(f"{byt/1e9:9.1f} GB  {op:16s} {label}")


if __name__ == "__main__":
    breakdown(sys.argv[1], int(sys.argv[2]) if len(sys.argv) > 2 else 30)
