"""Use Case 3 (Figs. 12-13): data parallelization — Dispatcher + 2 replicas
of a slow stateless OP3 + Merger; failures hit one replica while the other
keeps processing (LOG.io non-blocking advantage)."""
from __future__ import annotations

from benchmarks.common import bench, payload, t
from repro.core import (CountWindowOperator, GeneratorSource, MapOperator,
                        Pipeline, ReadSource, TerminalSink)
from repro.core.scaling import DispatcherOperator, MergerOperator


def build_uc3(*, n_events: int = 1000, rate_s: float = 0.1,
              op3_pt: float = 0.5, op5_window: int = 100, kb: float = 10.0):
    events = [payload(kb, i) for i in range(n_events)]
    n_out = n_events // op5_window

    def build():
        p = Pipeline()
        p.add(lambda: GeneratorSource("OP1", ReadSource(events),
                                      rate=t(rate_s)))
        p.add(lambda: DispatcherOperator("OP2", ["r0", "r1"]))
        p.add(lambda: MapOperator("r0", fn=lambda b: b,
                                  processing_time=t(op3_pt)))
        p.add(lambda: MapOperator("r1", fn=lambda b: b,
                                  processing_time=t(op3_pt)))
        p.add(lambda: MergerOperator("OP4", ["r0", "r1"]))
        p.add(lambda: CountWindowOperator(
            "OP5", op5_window, agg=lambda bs: {"n": len(bs)},
            writes_per_output=1))
        p.add(lambda: TerminalSink("OP6", target=max(n_out, 1)))
        p.connect("OP1", "out", "OP2", "in")
        p.connect("OP2", "to_r0", "r0", "in")
        p.connect("OP2", "to_r1", "r1", "in")
        p.connect("r0", "out", "OP4", "from_r0")
        p.connect("r1", "out", "OP4", "from_r1")
        p.connect("OP4", "out", "OP5", "in")
        p.connect("OP5", "out", "OP6", "in")
        return p
    return build


def run(rows, repeats=3, full=False):
    build = build_uc3()
    bench("uc3_fig12", build, repeats=repeats, rows=rows,
          plans={"normal": [],
                 "1fail_replica": [("r0", "input", 20)],
                 "3fail_replica": [("r0", "input", 20),
                                   ("r1", "input", 220),
                                   ("r0", "input", 330)]},
          abs_epoch=150)
    if full:
        fast = build_uc3(n_events=5000, rate_s=0.03, op3_pt=0.1,
                         op5_window=200)
        bench("uc3_fig13", fast, repeats=repeats,
              rows=rows,
              plans={"normal": [],
                     "1fail_replica": [("r0", "input", 10)]},
              abs_epoch=500)
